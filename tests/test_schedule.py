"""STSchedule / compose — multi-queue pipelined composition.

Fast lane: single-device (1,1,1 periodic grid) correctness of the
composed program against independent per-program runs (bit-equality —
composition must not perturb either program's numerics), structural
invariants of the interleaving, the per-program counter banks, the
error surface, and the halo front-end.

Slow lane: the same contrasts on a real 2×2×2 8-device grid
(subprocess, like tests/test_persistent.py).
"""

import dataclasses

import numpy as np
import pytest

from repro.core import (
    FacesConfig,
    FusedEngine,
    HostEngine,
    OffsetPeer,
    PersistentEngine,
    ScheduleError,
    STQueue,
    STSchedule,
    build_faces_program,
    compose,
    faces_oracle,
    half_config,
    merge_halves,
    merge_parts,
    run_faces_persistent,
    run_faces_pipelined,
    run_faces_until_converged,
    split_halves,
)
from repro.core.descriptors import (
    CollDesc,
    KernelDesc,
    RecvDesc,
    SendDesc,
    StartDesc,
    WaitDesc,
)
from repro.core.halo import AXES3
from repro.core.schedule import _segments


def _mesh111():
    from repro.parallel import make_mesh
    return make_mesh((1, 1, 1), AXES3)


def _meshx():
    from repro.parallel import make_mesh
    return make_mesh((1,), ("x",))


def _u0(cfg, seed=0):
    rng = np.random.RandomState(seed)
    return rng.randn(*cfg.grid, *cfg.points).astype(np.float32)


def _tiny_program(mesh, name, n_batches=1, waited=True):
    q = STQueue(mesh, name=name)
    q.buffer("a", (4,), np.float32, pspec=("x",))
    q.buffer("b", (4,), np.float32, pspec=("x",))
    for t in range(n_batches):
        q.enqueue_kernel(lambda a: a * 2.0, ["a"], ["a"], name=f"k{t}")
        q.enqueue_recv("b", OffsetPeer("x", -1, periodic=True), tag=t)
        q.enqueue_send("a", OffsetPeer("x", 1, periodic=True), tag=t)
        q.enqueue_start()
    if waited:
        q.enqueue_wait()
    return q.build()


# -- structure ----------------------------------------------------------------


class TestComposeStructure:
    def test_namespacing_and_sub_metadata(self):
        mesh = _meshx()
        pa = _tiny_program(mesh, "A", n_batches=2)
        pb = _tiny_program(mesh, "B", n_batches=1)
        sched = compose(pa, pb)
        assert isinstance(sched, STSchedule)
        assert sched.name == "A+B"
        assert set(sched.buffers) == {"A/a", "A/b", "B/a", "B/b"}
        assert sched.buffers["A/a"].name == "A/a"
        assert [s.name for s in sched.subs] == ["A", "B"]
        assert sched.buffers_by_pid() == {0: ("A/a", "A/b"),
                                          1: ("B/a", "B/b")}
        assert sched.buffer_name("B", "a") == "B/a"
        with pytest.raises(KeyError):
            sched.buffer_name("A", "nope")
        # batch indices renumbered to be globally unique, pids tagged
        assert sorted(b.index for b in sched.batches) == [0, 1, 2]
        assert [b.pid for b in sorted(sched.batches,
                                      key=lambda b: b.index)] == [0, 0, 1]
        # every descriptor carries its program identity
        for d in sched.descriptors:
            assert d.pid in (0, 1)
        # composition preserves totals
        assert sched.n_batches == pa.n_batches + pb.n_batches
        assert sched.n_channels == pa.n_channels + pb.n_channels
        assert (sched.dispatch_count_host()
                == pa.dispatch_count_host() + pb.dispatch_count_host())

    def test_round_robin_interleaving(self):
        """B's descriptors sit between A's start and A's wait gates."""
        mesh = _meshx()
        sched = compose(_tiny_program(mesh, "A"), _tiny_program(mesh, "B"))
        pids = [d.pid for d in sched.descriptors]
        # segments alternate: A's batch(+start), B's batch(+start),
        # A's wait, B's wait — so pid 1 appears before pid 0's last desc
        first_b = pids.index(1)
        last_a = len(pids) - 1 - pids[::-1].index(0)
        assert first_b < last_a
        # A's wait comes after B's start: B's batch is inside A's
        # start→wait window (the software-pipelining overlap)
        a_wait = next(i for i, d in enumerate(sched.descriptors)
                      if isinstance(d, WaitDesc) and d.pid == 0)
        b_start = next(i for i, d in enumerate(sched.descriptors)
                       if isinstance(d, StartDesc) and d.pid == 1)
        assert b_start < a_wait

    def test_fifo_order_preserved_per_program(self):
        mesh = _meshx()
        pa = _tiny_program(mesh, "A", n_batches=3)
        pb = _tiny_program(mesh, "B", n_batches=2)
        sched = compose(pa, pb)
        for pid, orig in ((0, pa), (1, pb)):
            mine = [d for d in sched.descriptors if d.pid == pid]
            assert len(mine) == len(orig.descriptors)
            for got, want in zip(mine, orig.descriptors):
                assert type(got) is type(want)
                if isinstance(want, (SendDesc, RecvDesc)):
                    assert got.buf.split("/", 1)[1] == want.buf
                    assert got.tag == want.tag
                elif isinstance(want, KernelDesc):
                    assert got.name == want.name

    def test_segments_keep_batches_whole(self):
        """A wait between a batch's recvs and its start must not split
        the batch across segments."""
        mesh = _meshx()
        q = STQueue(mesh, "W")
        q.buffer("a", (4,), np.float32, pspec=("x",))
        q.buffer("b", (4,), np.float32, pspec=("x",))
        q.enqueue_recv("b", OffsetPeer("x", -1, periodic=True), tag=0)
        q.enqueue_send("a", OffsetPeer("x", 1, periodic=True), tag=0)
        q.enqueue_start()
        q.enqueue_recv("b", OffsetPeer("x", -1, periodic=True), tag=1)
        q.enqueue_wait()  # wait on batch 0, in the middle of batch 1
        q.enqueue_send("a", OffsetPeer("x", 1, periodic=True), tag=1)
        q.enqueue_start()
        q.enqueue_wait()
        segs = _segments(list(q.build().descriptors))
        for seg in segs:
            # no segment may end with a batch half-open
            open_comm = 0
            for d in seg:
                if isinstance(d, (SendDesc, RecvDesc, CollDesc)):
                    open_comm += 1
                elif isinstance(d, StartDesc):
                    open_comm = 0
            assert open_comm == 0

    def test_compose_three_programs(self):
        mesh = _meshx()
        sched = compose(*[_tiny_program(mesh, n) for n in "ABC"])
        assert [s.pid for s in sched.subs] == [0, 1, 2]
        assert len(sched.buffers) == 6
        assert sorted(b.index for b in sched.batches) == [0, 1, 2]


# -- error surface ------------------------------------------------------------


class TestComposeErrors:
    def test_duplicate_names_rejected_as_aliasing(self):
        mesh = _meshx()
        pa = _tiny_program(mesh, "A")
        with pytest.raises(ScheduleError, match="alias"):
            compose(pa, pa)  # a program composed with itself

    def test_mesh_mismatch_rejected(self):
        from repro.parallel import make_mesh
        pa = _tiny_program(make_mesh((1,), ("x",)), "A")
        pb = dataclasses.replace(_tiny_program(make_mesh((1,), ("x",)), "B"),
                                 mesh=make_mesh((1,), ("y",)))
        with pytest.raises(ScheduleError, match="mesh"):
            compose(pa, pb)

    def test_nested_schedule_rejected(self):
        mesh = _meshx()
        sched = compose(_tiny_program(mesh, "A"), _tiny_program(mesh, "B"))
        with pytest.raises(ScheduleError, match="nested"):
            compose(sched, _tiny_program(mesh, "C"))

    def test_empty_compose_rejected(self):
        with pytest.raises(ScheduleError):
            compose()

    def test_schedule_persistent_is_per_program(self):
        mesh = _meshx()
        sched = compose(_tiny_program(mesh, "A"), _tiny_program(mesh, "B"))
        with pytest.raises(ScheduleError, match="per-program"):
            sched.persistent(4)

    def test_concurrent_with_sugar(self):
        mesh = _meshx()
        pa, pb = _tiny_program(mesh, "A"), _tiny_program(mesh, "B")
        sched = pa.concurrent_with(pb, name="pair")
        assert isinstance(sched, STSchedule) and sched.name == "pair"

    def test_engine_rejects_global_knobs_on_schedule(self):
        mesh = _meshx()
        sched = compose(_tiny_program(mesh, "A"), _tiny_program(mesh, "B"))
        with pytest.raises(ValueError, match="n_iters"):
            PersistentEngine(sched, n_iters=3)
        with pytest.raises(ValueError, match="does not apply"):
            PersistentEngine(sched, cond_fn=lambda r: r > 0,
                             reduce_fn=lambda m: 0.0)
        with pytest.raises(ValueError, match="unknown sub-program"):
            PersistentEngine(sched, reduce_fns={"nope": lambda m: 0.0})

    def test_engine_requires_reduce_for_predicated_sub(self):
        mesh = _mesh111()
        cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3), periodic=True)
        pa = build_faces_program(cfg, mesh, name="A").persistent(
            4, until=lambda r: r >= 1e-3)
        pb = build_faces_program(cfg, mesh, name="B").persistent(4)
        with pytest.raises(ValueError, match="reduce_fns"):
            PersistentEngine(compose(pa, pb))

    def test_plain_program_rejects_reduce_fns(self):
        mesh = _mesh111()
        cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3), periodic=True)
        prog = build_faces_program(cfg, mesh)
        with pytest.raises(ValueError, match="reduce_fns"):
            PersistentEngine(prog, reduce_fns={"faces": lambda m: 0.0})


# -- correctness (fast, single device) ---------------------------------------


@pytest.mark.parametrize("mode", ["stream", "dataflow"])
def test_composed_fixed_bitmatches_independent(mode):
    """compose(A, B).persistent-run == two independent persistent runs,
    bit for bit, in ONE dispatch instead of two."""
    n = 3
    cfg = FacesConfig(grid=(1, 1, 1), points=(4, 3, 5), periodic=True)
    mesh = _mesh111()
    ua, ub = _u0(cfg, seed=1), _u0(cfg, seed=2)
    pa = build_faces_program(cfg, mesh, name="facesA").persistent(n)
    pb = build_faces_program(cfg, mesh, name="facesB").persistent(n)
    sched = compose(pa, pb)

    eng = PersistentEngine(sched, mode=mode)
    out = eng(eng.init_buffers({"facesA/u": ua, "facesB/u": ub}))
    assert eng.stats.dispatches == 1

    total = 0
    for nm, u in (("facesA", ua), ("facesB", ub)):
        mem, stats = run_faces_persistent(cfg, mesh, u, n_iters=n, mode=mode)
        total += stats.dispatches
        np.testing.assert_array_equal(np.asarray(out[f"{nm}/u"]),
                                      np.asarray(mem["u"]), err_msg=nm)
    assert total == 2  # sequential costs one dispatch per queue


def test_composed_mixed_iteration_counts():
    """Sub-programs with different n_iters: each freezes at its own
    count (masked loop), matching its independent run exactly."""
    cfg = FacesConfig(grid=(1, 1, 1), points=(4, 4, 4), periodic=True)
    mesh = _mesh111()
    ua, ub = _u0(cfg, seed=3), _u0(cfg, seed=4)
    pa = build_faces_program(cfg, mesh, name="facesA").persistent(2)
    pb = build_faces_program(cfg, mesh, name="facesB").persistent(5)
    eng = PersistentEngine(compose(pa, pb), mode="dataflow")
    mem, reds, n_done = eng(eng.init_buffers({"facesA/u": ua,
                                              "facesB/u": ub}))
    assert reds == {}
    assert int(n_done["facesA"]) == 2 and int(n_done["facesB"]) == 5
    assert eng.stats.dispatches == 1
    for nm, u, n in (("facesA", ua, 2), ("facesB", ub, 5)):
        ind, _ = run_faces_persistent(cfg, mesh, u, n_iters=n)
        np.testing.assert_array_equal(np.asarray(mem[f"{nm}/u"]),
                                      np.asarray(ind["u"]), err_msg=nm)


@pytest.mark.parametrize("double_buffer", [True, False])
def test_composed_per_program_predicates(double_buffer):
    """Each (unlinked) half runs to its OWN tolerance inside one
    dispatch and bit-matches an independent until-converged run (the
    acceptance contrast of the pipelined multi-queue schedule;
    exchange=False keeps the halves independent)."""
    cfg = FacesConfig(grid=(1, 1, 1), points=(6, 3, 4), periodic=True,
                      damping=0.12)
    u0 = _u0(cfg, seed=5)
    mesh = _mesh111()
    tols = (1e-1, 1e-3)
    mem, reds, n_done, stats = run_faces_pipelined(
        cfg, mesh, u0, tols=tols, max_iters=50,
        double_buffer=double_buffer, exchange=False)
    assert stats.dispatches == 1 and stats.sync_points == 0
    assert n_done["facesA"] < n_done["facesB"] < 50  # both converged

    cfgh = half_config(cfg)
    ua, ub = split_halves(u0)
    for nm, u, tol in (("facesA", ua, tols[0]), ("facesB", ub, tols[1])):
        ind_mem, ind_res, ind_n, ind_stats = run_faces_until_converged(
            cfgh, mesh, u, tol=tol, max_iters=50,
            double_buffer=double_buffer)
        assert ind_n == n_done[nm]
        np.testing.assert_array_equal(np.asarray(mem[f"{nm}/u"]),
                                      np.asarray(ind_mem["u"]), err_msg=nm)
        np.testing.assert_array_equal(reds[nm], ind_res, err_msg=nm)


def test_pipelined_unlinked_matches_per_half_oracle():
    """exchange=False keeps the PR-3 semantics: each half is its own
    independent solve (per-half oracle, NOT the full-domain update)."""
    cfg = FacesConfig(grid=(1, 1, 1), points=(6, 4, 3), periodic=True)
    u0 = _u0(cfg, seed=6)
    mesh = _mesh111()
    mem, stats = run_faces_pipelined(cfg, mesh, u0, n_iters=3,
                                     exchange=False)
    assert stats.dispatches == 1
    cfgh = half_config(cfg)
    refs = []
    for u in split_halves(u0):
        ref = np.asarray(u)
        for _ in range(3):
            ref = faces_oracle(ref, cfgh)
        refs.append(ref)
    got = np.asarray(merge_halves(mem["facesA/u"], mem["facesB/u"]))
    np.testing.assert_allclose(got, np.concatenate(refs, axis=3),
                               rtol=1e-4, atol=1e-4)


def test_split_merge_roundtrip_uneven_and_errors():
    from repro.core import part_configs, part_points, split_parts

    cfg = FacesConfig(grid=(1, 1, 1), points=(6, 4, 3))
    u0 = _u0(cfg)
    ua, ub = split_halves(u0)
    np.testing.assert_array_equal(np.asarray(merge_halves(ua, ub)), u0)
    # odd sizes split unevenly instead of erroring (first part larger)
    odd = _u0(FacesConfig(grid=(1, 1, 1), points=(5, 4, 3)))
    oa, ob = split_halves(odd)
    assert oa.shape[3] == 3 and ob.shape[3] == 2
    np.testing.assert_array_equal(np.asarray(merge_halves(oa, ob)), odd)
    assert part_points(7, 3) == (3, 2, 2)
    assert [c.points[0] for c in part_configs(cfg, 4)] == [2, 2, 1, 1]
    parts = split_parts(u0, 4)
    np.testing.assert_array_equal(np.asarray(merge_parts(parts)), u0)
    with pytest.raises(ValueError, match="n_parts"):
        part_points(3, 4)  # more parts than planes
    with pytest.raises(ValueError, match="exactly one"):
        run_faces_pipelined(cfg, _mesh111(), u0)
    with pytest.raises(ValueError, match="max_iters"):
        run_faces_pipelined(cfg, _mesh111(), u0, tols=(1e-2, 1e-3))
    with pytest.raises(ValueError, match="per part"):
        run_faces_pipelined(cfg, _mesh111(), u0, tols=(1e-2,), max_iters=5)


@pytest.mark.parametrize("engine_cls", [FusedEngine, HostEngine])
def test_single_pass_engines_run_composed_programs(engine_cls):
    """The one-pass engines execute a composed schedule too — same
    results as running each program through them separately."""
    cfg = FacesConfig(grid=(1, 1, 1), points=(4, 3, 3), periodic=True)
    mesh = _mesh111()
    ua, ub = _u0(cfg, seed=7), _u0(cfg, seed=8)
    pa = build_faces_program(cfg, mesh, name="facesA")
    pb = build_faces_program(cfg, mesh, name="facesB")
    eng = engine_cls(compose(pa, pb))
    out = eng(eng.init_buffers({"facesA/u": ua, "facesB/u": ub}))
    for nm, prog, u in (("facesA", pa, ua), ("facesB", pb, ub)):
        ind = engine_cls(prog)
        mem = ind(ind.init_buffers({"u": u}))
        np.testing.assert_allclose(np.asarray(out[f"{nm}/u"]),
                                   np.asarray(mem["u"]),
                                   rtol=1e-6, atol=1e-6, err_msg=nm)


def test_composed_reduce_traces_without_predicates():
    """reduce_fns alone (no until) routes through the masked loop and
    records every sub's trace."""
    import jax
    import jax.numpy as jnp

    cfg = FacesConfig(grid=(1, 1, 1), points=(3, 3, 3), periodic=True)
    mesh = _mesh111()
    pa = build_faces_program(cfg, mesh, name="facesA").persistent(3)
    pb = build_faces_program(cfg, mesh, name="facesB").persistent(3)

    def norm(buf):
        return lambda mem: jax.lax.psum(
            jnp.sum(mem[buf].astype(jnp.float32) ** 2), AXES3)

    eng = PersistentEngine(compose(pa, pb), mode="dataflow",
                           reduce_fns={"facesA": norm("facesA/u"),
                                       "facesB": norm("facesB/u")})
    ua, ub = _u0(cfg, seed=9), _u0(cfg, seed=10)
    mem, reds, n_done = eng(eng.init_buffers({"facesA/u": ua,
                                              "facesB/u": ub}))
    assert set(reds) == {"facesA", "facesB"}
    assert reds["facesA"].shape == (3,) and reds["facesB"].shape == (3,)
    assert int(n_done["facesA"]) == int(n_done["facesB"]) == 3
    # cross-check one trace against the plain persistent engine
    prog = build_faces_program(cfg, mesh).persistent(3)
    ref = PersistentEngine(prog, mode="dataflow", reduce_fn=norm("u"))
    _, ref_red = ref(ref.init_buffers({"u": ua}))
    np.testing.assert_array_equal(np.asarray(reds["facesA"]),
                                  np.asarray(ref_red))


# -- multi-device matrix (subprocess, slow lane) ------------------------------


@pytest.mark.slow
def test_composed_matches_independent_8dev(subproc):
    r = subproc("""
import numpy as np
from repro.core import (FacesConfig, PersistentEngine, build_faces_program,
                        compose, half_config, run_faces_persistent,
                        run_faces_pipelined, run_faces_until_converged,
                        split_halves)
from repro.parallel import make_mesh

mesh = make_mesh((2, 2, 2), ("gx", "gy", "gz"))
cfg = FacesConfig(grid=(2, 2, 2), points=(6, 4, 4), damping=0.12)
u0 = np.random.RandomState(0).randn(2, 2, 2, 6, 4, 4).astype(np.float32)
N = 3

# fixed-count composed loop (exchange=False: independent halves), both
# modes.  Stream mode is bit-exact.  Dataflow mode drifts at the ULP
# level: pinned down (PR 5) to the *coalesced* lowering under dataflow
# ordering — the fused-transfer pack/slice gives XLA a different fusion
# context than the per-channel program, so some mul-add chains contract
# to FMA in one compilation but not the other (transport itself is
# verbatim; with coalesce=False or stream ordering the comparison is
# exact — asserted in tests/test_links.py).  Per-element the divergence
# is a few eps, amplified by the 26-direction accumulation each
# iteration: the DOCUMENTED bound is rtol=1e-6 (~8 eps) with atol=1e-7
# for the damped near-zero tail.  Do not widen these without updating
# the analysis above.
DRIFT_RTOL, DRIFT_ATOL = 1e-6, 1e-7
for mode in ("stream", "dataflow"):
    mem, stats = run_faces_pipelined(cfg, mesh, u0, n_iters=N, mode=mode,
                                     exchange=False)
    assert stats.dispatches == 1
    cfgh = half_config(cfg)
    for nm, u in zip(("facesA", "facesB"), split_halves(u0)):
        ind, _ = run_faces_persistent(cfgh, mesh, u, n_iters=N, mode=mode)
        if mode == "stream":
            np.testing.assert_array_equal(np.asarray(mem[f"{nm}/u"]),
                                          np.asarray(ind["u"]))
        else:
            np.testing.assert_allclose(np.asarray(mem[f"{nm}/u"]),
                                       np.asarray(ind["u"]),
                                       rtol=DRIFT_RTOL, atol=DRIFT_ATOL)

# per-program predicates on the real grid (dataflow default)
tols = (1e-1, 1e-2)
mem, reds, n_done, stats = run_faces_pipelined(
    cfg, mesh, u0, tols=tols, max_iters=40, exchange=False)
assert stats.dispatches == 1
cfgh = half_config(cfg)
for nm, u, tol in zip(("facesA", "facesB"), split_halves(u0), tols):
    im, ir, inn, _ = run_faces_until_converged(cfgh, mesh, u, tol=tol,
                                               max_iters=40)
    assert inn == n_done[nm], (nm, inn, n_done[nm])
    np.testing.assert_allclose(np.asarray(mem[f"{nm}/u"]),
                               np.asarray(im["u"]),
                               rtol=DRIFT_RTOL, atol=DRIFT_ATOL)
    np.testing.assert_allclose(reds[nm], ir, rtol=1e-6)
print("composed 8dev OK")
""")
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "composed 8dev OK" in r.stdout
