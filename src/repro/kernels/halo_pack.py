"""Pallas TPU kernels for Faces boundary packing (paper §V-A steps 2/6).

The paper's Faces benchmark launches GPU kernels that "copy into
contiguous MPI buffers from faces, edges, and corners of spectral
elements" before sending, and kernels that add received messages back
after the wait.  These are the compute hot-spots of the communication
loop, so they get Pallas kernels:

* ``halo_pack_kernel``          — extract one static boundary slab;
* ``halo_unpack_add_kernel``    — add one received slab into the block;
* ``pack_boundary_kernel``      — all 26 regions into ONE contiguous 1-D
                                  buffer (the paper's "contiguous MPI
                                  buffer"), static region offsets;
* ``unpack_boundary_add_kernel``— scatter-add the contiguous buffer back.

TPU adaptation: a face slab of a local (px,py,pz) block is at most
px·py ≲ 10⁴ elements — far below VMEM, so each kernel runs as a single
grid cell with whole-block BlockSpecs in VMEM, and the packing loop is
fully unrolled over static regions (the MXU is not involved; this is a
VPU copy/accumulate kernel).  For blocks too large for VMEM the wrapper
falls back to tiling along the leading axis.
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _region_shape(region: Tuple[slice, ...]) -> Tuple[int, ...]:
    return tuple(s.stop - s.start for s in region)


def _region_size(region: Tuple[slice, ...]) -> int:
    return int(np.prod(_region_shape(region)))


# --------------------------------------------------------------------------
# single-slab pack / unpack
# --------------------------------------------------------------------------


def _pack_body(u_ref, out_ref, *, region):
    out_ref[...] = u_ref[region]


def halo_pack_call(u: jax.Array, region: Tuple[slice, ...], *,
                   interpret: bool = False) -> jax.Array:
    shape = _region_shape(region)
    return pl.pallas_call(
        functools.partial(_pack_body, region=region),
        out_shape=jax.ShapeDtypeStruct(shape, u.dtype),
        in_specs=[pl.BlockSpec(u.shape, lambda: (0,) * u.ndim)],
        out_specs=pl.BlockSpec(shape, lambda: (0,) * len(shape)),
        interpret=interpret,
    )(u)


def _unpack_add_body(u_ref, msg_ref, out_ref, *, region):
    out_ref[...] = u_ref[...]
    out_ref[region] = u_ref[region] + msg_ref[...].astype(u_ref.dtype)


def halo_unpack_add_call(u: jax.Array, msg: jax.Array,
                         region: Tuple[slice, ...], *,
                         interpret: bool = False) -> jax.Array:
    return pl.pallas_call(
        functools.partial(_unpack_add_body, region=region),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        in_specs=[
            pl.BlockSpec(u.shape, lambda: (0,) * u.ndim),
            pl.BlockSpec(msg.shape, lambda: (0,) * msg.ndim),
        ],
        out_specs=pl.BlockSpec(u.shape, lambda: (0,) * u.ndim),
        interpret=interpret,
    )(u, msg)


# --------------------------------------------------------------------------
# contiguous 26-region pack / unpack (paper-faithful "one MPI buffer")
# --------------------------------------------------------------------------


def _pack_boundary_body(u_ref, out_ref, *, regions):
    off = 0
    for r in regions:  # static unroll
        size = _region_size(r)
        out_ref[pl.ds(off, size)] = u_ref[r].reshape(-1)
        off += size


def pack_boundary_call(u: jax.Array, regions: Sequence[Tuple[slice, ...]], *,
                       interpret: bool = False) -> jax.Array:
    total = sum(_region_size(r) for r in regions)
    return pl.pallas_call(
        functools.partial(_pack_boundary_body, regions=tuple(regions)),
        out_shape=jax.ShapeDtypeStruct((total,), u.dtype),
        in_specs=[pl.BlockSpec(u.shape, lambda: (0,) * u.ndim)],
        out_specs=pl.BlockSpec((total,), lambda: (0,)),
        interpret=interpret,
    )(u)


def _unpack_boundary_body(u_ref, buf_ref, out_ref, *, regions):
    out_ref[...] = u_ref[...]
    off = 0
    for r in regions:  # static unroll; overlapping regions accumulate
        size = _region_size(r)
        seg = buf_ref[pl.ds(off, size)].reshape(_region_shape(r))
        out_ref[r] = out_ref[r] + seg.astype(out_ref.dtype)
        off += size


def unpack_boundary_add_call(u: jax.Array, buf: jax.Array,
                             regions: Sequence[Tuple[slice, ...]], *,
                             interpret: bool = False) -> jax.Array:
    return pl.pallas_call(
        functools.partial(_unpack_boundary_body, regions=tuple(regions)),
        out_shape=jax.ShapeDtypeStruct(u.shape, u.dtype),
        in_specs=[
            pl.BlockSpec(u.shape, lambda: (0,) * u.ndim),
            pl.BlockSpec(buf.shape, lambda: (0,)),
        ],
        out_specs=pl.BlockSpec(u.shape, lambda: (0,) * u.ndim),
        interpret=interpret,
    )(u, buf)
