"""Multi-device behaviour (subprocess with 8 forced host devices).

The main pytest process keeps the single real CPU device (see
conftest.py); everything here runs in fresh subprocesses with
``--xla_force_host_platform_device_count=8``.
"""

import textwrap

import pytest


def _check(subproc, code, devices=8):
    r = subproc(textwrap.dedent(code), devices=devices)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


@pytest.mark.slow
def test_faces_engines_match_oracle(subproc):
    _check(subproc, """
        import numpy as np, jax
        from repro.core import (FacesConfig, FusedEngine, HostEngine,
                                build_faces_program, faces_oracle)
        from repro.parallel import make_mesh
        mesh = make_mesh((2, 2, 2), ("gx", "gy", "gz"))
        cfg = FacesConfig(grid=(2, 2, 2), points=(5, 4, 3))
        prog = build_faces_program(cfg, mesh)
        u0 = np.random.RandomState(0).randn(2, 2, 2, 5, 4, 3).astype(np.float32)
        ref = faces_oracle(u0, cfg)
        for mode in ("stream", "dataflow"):
            eng = FusedEngine(prog, mode=mode)
            out = eng(eng.init_buffers({"u": u0}))
            np.testing.assert_allclose(np.asarray(out["u"]), ref, rtol=1e-5, atol=1e-5)
        host = HostEngine(prog)
        out = host(host.init_buffers({"u": u0}))
        np.testing.assert_allclose(np.asarray(out["u"]), ref, rtol=1e-5, atol=1e-5)
        assert host.stats.dispatches == prog.dispatch_count_host()
        assert host.stats.sync_points >= host.stats.dispatches
    """)


@pytest.mark.slow
def test_faces_fused_equals_host_bitwise_pathwise(subproc):
    """The two engines are the paper's A/B: results must agree exactly
    (same math, different control path)."""
    _check(subproc, """
        import numpy as np, jax
        from repro.core import FacesConfig, FusedEngine, HostEngine, build_faces_program
        from repro.parallel import make_mesh
        mesh = make_mesh((4, 1, 2), ("gx", "gy", "gz"))
        cfg = FacesConfig(grid=(4, 1, 2), points=(4, 3, 5), periodic=True)
        prog = build_faces_program(cfg, mesh)
        u0 = np.random.RandomState(1).randn(4, 1, 2, 4, 3, 5).astype(np.float32)
        f = FusedEngine(prog, mode="dataflow"); h = HostEngine(prog)
        a = f(f.init_buffers({"u": u0}))["u"]
        b = h(h.init_buffers({"u": u0}))["u"]
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)
    """)


@pytest.mark.slow
def test_staged3_matches_its_oracle(subproc):
    """Staged (3-sweep) halo: each sweep's sum equals a numpy emulation."""
    _check(subproc, """
        import numpy as np, jax
        from repro.core import FacesConfig, FusedEngine, build_faces_program
        from repro.core.halo import FACES, _region_for
        from repro.parallel import make_mesh
        mesh = make_mesh((2, 2, 2), ("gx", "gy", "gz"))
        cfg = FacesConfig(grid=(2, 2, 2), points=(4, 4, 4), granularity="staged3",
                          interior_compute=False)
        prog = build_faces_program(cfg, mesh)
        u0 = np.random.RandomState(2).randn(2, 2, 2, 4, 4, 4).astype(np.float32)
        eng = FusedEngine(prog, mode="stream")
        out = np.asarray(eng(eng.init_buffers({"u": u0}))["u"])

        # numpy emulation of the same staged schedule
        ref = u0.copy()
        for axis in (0, 1, 2):
            dirs = [d for d in FACES if d[axis] != 0]
            packed = {d: ref[(slice(None),)*3 + _region_for(d, cfg.points)].copy()
                      for d in dirs}
            for d in dirs:
                msg = packed[d]
                shifted = np.zeros_like(msg)
                src = [slice(None)]*6; dst = [slice(None)]*6
                n = (2, 2, 2)[axis]; delta = d[axis]
                if delta > 0:
                    src[axis] = slice(0, n - delta); dst[axis] = slice(delta, n)
                else:
                    src[axis] = slice(-delta, n); dst[axis] = slice(0, n + delta)
                shifted[tuple(dst)] = msg[tuple(src)]
                region = _region_for(tuple(-x for x in d), cfg.points)
                ref[(slice(None),)*3 + region] += shifted
        np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)
    """)


@pytest.mark.slow
def test_overlap_collectives_match_lax(subproc):
    _check(subproc, """
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from repro.core import overlap
        from repro.parallel import make_mesh
        from jax.sharding import PartitionSpec as P
        mesh = make_mesh((8,), ("x",))
        x = np.random.RandomState(0).randn(32, 16).astype(np.float32)

        from repro.compat import jit_shard_map
        def smap(f, in_spec, out_spec):
            return jit_shard_map(f, mesh=mesh, in_specs=in_spec,
                                 out_specs=out_spec)

        # all_gather_ring (both directions) == lax.all_gather
        for bidi in (False, True):
            got = smap(partial(overlap.all_gather_ring, axis="x", bidirectional=bidi),
                       (P("x"),), P())(x)
            np.testing.assert_allclose(np.asarray(got), x, rtol=1e-6)

        # reduce_scatter_ring == psum_scatter
        got = smap(partial(overlap.reduce_scatter_ring, axis="x"),
                   (P(None, None),), P("x"))(x)
        want = smap(lambda v: jax.lax.psum_scatter(v, "x", scatter_dimension=0,
                                                   tiled=True),
                    (P(None, None),), P("x"))(x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

        # all_gather_matmul == (all_gather @ w)
        w = np.random.RandomState(1).randn(16, 8).astype(np.float32)
        got = smap(partial(overlap.all_gather_matmul, axis="x"),
                   (P("x"), P()), P())(x, w)
        np.testing.assert_allclose(np.asarray(got), x @ w, rtol=1e-4, atol=1e-5)

        # matmul_reduce_scatter == reduce_scatter(x_part @ w_part)
        xk = np.random.RandomState(2).randn(32, 64).astype(np.float32)
        wk = np.random.RandomState(3).randn(64, 8).astype(np.float32)
        got = smap(partial(overlap.matmul_reduce_scatter, axis="x"),
                   (P(None, "x"), P("x")), P("x"))(xk, wk)
        full = xk @ wk
        np.testing.assert_allclose(np.asarray(got), full, rtol=1e-4, atol=1e-4)

        # all_to_all_ppermute == lax.all_to_all
        xa = np.random.RandomState(4).randn(64, 4).astype(np.float32)
        got = smap(partial(overlap.all_to_all_ppermute, axis="x"),
                   (P("x"),), P("x"))(xa)
        want = smap(lambda v: jax.lax.all_to_all(v, "x", split_axis=0,
                                                 concat_axis=0, tiled=True),
                    (P("x"),), P("x"))(xa)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
        print("overlap OK")
    """)


@pytest.mark.slow
def test_sharded_train_step_runs(subproc):
    """2x2 (data x model) sharded train step on the smallest arch."""
    _check(subproc, """
        import numpy as np, jax
        from repro.configs.base import ShapeConfig, get_config
        from repro.launch.train import train
        from repro.optim import AdamWConfig
        from repro.parallel import make_mesh
        cfg = get_config("qwen1.5-0.5b").smoke()
        mesh = make_mesh((2, 2), ("data", "model"))
        shape = ShapeConfig("t", 32, 4, "train")
        params, opt_state, hist = train(cfg, shape, mesh, steps=6,
                                        opt=AdamWConfig(lr=1e-3), log_every=5)
        losses = [h["loss"] for h in hist]
        assert all(np.isfinite(l) for l in losses)
        assert losses[-1] < losses[0] + 0.5  # not diverging
    """, devices=4)


@pytest.mark.slow
def test_fused_engine_lowers_single_program(subproc):
    """The ST engine's whole program is ONE executable; the host engine
    dispatches per descriptor (the paper's control-path contrast)."""
    _check(subproc, """
        from repro.core import FacesConfig, FusedEngine, HostEngine, build_faces_program
        from repro.parallel import make_mesh
        import numpy as np
        mesh = make_mesh((2, 2, 2), ("gx", "gy", "gz"))
        cfg = FacesConfig(grid=(2, 2, 2), points=(4, 4, 4))
        prog = build_faces_program(cfg, mesh)
        eng = FusedEngine(prog, mode="dataflow")
        lowered = eng.lower()
        text = lowered.as_text()
        assert "collective" in text or "ppermute" in text  # comm present
        host = HostEngine(prog)
        out = host(host.init_buffers({"u": np.ones((2,2,2,4,4,4), np.float32)}))
        assert host.stats.dispatches == prog.dispatch_count_host() > 1
    """)


@pytest.mark.slow
def test_expert_parallel_moe_matches_gather(subproc):
    """shard_map EP dispatch == auto-partitioned gather dispatch (ample
    capacity ⇒ no drops ⇒ identical math) on a data×model mesh."""
    _check(subproc, """
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import get_config
        from repro.models import Model, moe as moe_lib
        from repro.parallel import RULES_TRAIN, make_mesh, sharding_ctx
        cfg = dataclasses.replace(get_config("deepseek-v3-671b").smoke(),
                                  capacity_factor=8.0)
        m = Model(cfg)
        params, _ = m.init(jax.random.PRNGKey(0))
        p = params["decoder"]["segments"][1][0]["moe"]
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(4, 8, cfg.d_model), jnp.float32)
        mesh = make_mesh((2, 2), ("data", "model"))
        y_gather, _ = moe_lib.apply_moe(
            dataclasses.replace(cfg, moe_impl="gather") and p, x,
            dataclasses.replace(cfg, moe_impl="gather"))
        with mesh, sharding_ctx(RULES_TRAIN, mesh):
            out = moe_lib.apply_moe_ep(p, x, cfg)
            assert out is not None, "EP path did not engage"
            y_ep, aux = out
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_gather),
                                   rtol=2e-4, atol=2e-4)
        assert float(aux["dropped_frac"]) == 0.0
        print("EP == gather OK")
    """, devices=4)


@pytest.mark.slow
def test_expert_parallel_moe_virtual_experts(subproc):
    """E < model-axis (grok case): F-split virtual experts == gather."""
    _check(subproc, """
        import dataclasses, numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import get_config
        from repro.models import Model, moe as moe_lib
        from repro.parallel import RULES_TRAIN, make_mesh, sharding_ctx
        cfg = dataclasses.replace(get_config("grok-1-314b").smoke(),
                                  n_experts=2, top_k=1, capacity_factor=8.0,
                                  d_ff_expert=64)
        m = Model(cfg)
        params, _ = m.init(jax.random.PRNGKey(0))
        p = params["decoder"]["segments"][0][0]["moe"]
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(4, 8, cfg.d_model), jnp.float32)
        mesh = make_mesh((1, 4), ("data", "model"))  # E=2 < model=4 → r=2
        y_gather, _ = moe_lib.apply_moe(
            p, x, dataclasses.replace(cfg, moe_impl="gather"))
        with mesh, sharding_ctx(RULES_TRAIN, mesh):
            out = moe_lib.apply_moe_ep(p, x, cfg)
            assert out is not None, "virtual-expert EP did not engage"
            y_ep, aux = out
        np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_gather),
                                   rtol=2e-4, atol=2e-4)
        print("virtual-expert EP OK")
    """, devices=4)
