"""Schedule auto-tuner: predict with the cost model, prove with medians.

Closes the loop the ROADMAP calls "auto-tuning from a cost model": a
built ST program has a *discrete knob space* — execution-configuration
choices that never change its numerics, only its lowering and schedule
— and this module searches it with the analytic model
(:func:`repro.launch.costing.schedule_cost`) pruning candidates before
anything is compiled, the bench harness's median-of-repeats loop
deciding winners, and STLint re-verifying every candidate before it is
ever timed (an invalid program can never publish a number).

Knob catalog
------------
``mode``            ``"stream" | "dataflow"`` — trigger/wait ordering
                    strictness (:class:`~repro.core.engine_fused
                    .FusedEngine`).  fig12's original single knob.
``coalesce``        execute the batches' build-time
                    :class:`~repro.core.matching.CoalescePlan`\\ s
                    (fused by-axis transfers) or the per-channel
                    lowering.
``double_buffer``   alternate message-slot copies between persistent
                    iterations (``None`` = engine default: on in
                    dataflow mode).
``unroll``          persistent ``fori_loop`` unroll factor (``None`` =
                    engine default, derived from ``double_buffer``).
``interleave``      the :func:`~repro.core.schedule.compose` segment
                    policy: a name from :data:`~repro.core.schedule
                    .INTERLEAVE_POLICIES` (``"round_robin"`` /
                    ``"sequential"``) or an int granularity (segments
                    one program emits per turn).
``n_parts`` /       domain-decomposition shape for builders that split
``split_points``    (:func:`repro.core.halo.part_points` convention);
                    carried on :class:`Knobs` for builders to consume —
                    the tuner itself never rebuilds domains.

Search strategy
---------------
:func:`tune` takes a ``build(knobs)`` callable returning ``(engine,
fresh)`` — engine wrapping the candidate program, ``fresh()`` a factory
for its input buffers — plus a ``space`` mapping knob names to value
lists.  The cartesian product is enumerated (these spaces are tiny:
tens, not thousands); each candidate is **built** (builder exceptions
— e.g. :class:`~repro.core.schedule.ScheduleError` for an impossible
interleaving — mark it invalid rather than aborting the search),
**verified** (error-severity STLint diagnostics disqualify),
optionally **certified** (``certify=True`` proves each candidate's
per-buffer effect trace identical to the ``base``-knob program's via
:func:`repro.core.effects.certify_equivalence` — non-equivalent
candidates are disqualified before they are ever priced or timed, and
equivalent ones skip the numeric ``check`` callback), and **priced**
analytically.  Only the ``measure_top`` cheapest predictions are
compiled and timed (median of ``repeats``); the fastest measured
median wins.  Ties in prediction are broken by knob order, so the
search is deterministic.

How to add a knob
-----------------
1. Add the field (with its engine-default value) to :class:`Knobs`.
2. Teach the relevant layer to accept it (an engine constructor
   parameter, a ``compose``/builder argument, …) and make your
   ``build(knobs)`` forward it.
3. If the knob changes the *schedule shape*, make sure
   :func:`~repro.launch.costing.schedule_cost` can see the difference
   (e.g. the interleave policy shows up as stream switches) — a knob
   the model is blind to still works, it just can't be pruned on.
4. List its candidate values in the ``space`` you pass to :func:`tune`.

The chosen knobs are published into ``BENCH_faces.json``'s ``_meta``
stamp (see ``benchmarks/run.py``) so the CI perf gate pins them and
flags drift when a re-tune would now pick differently.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class Knobs:
    """One point in the discrete tuning space (numerics-preserving)."""

    mode: str = "dataflow"
    coalesce: bool = True
    double_buffer: Optional[bool] = None
    unroll: Optional[int] = None
    interleave: Union[str, int] = "round_robin"
    n_parts: Optional[int] = None
    split_points: Optional[Tuple[int, ...]] = None

    def interleave_policy(self):
        """Resolve the ``interleave`` knob to an ``InterleavePolicy``."""
        from repro.core.schedule import INTERLEAVE_POLICIES, InterleavePolicy
        if isinstance(self.interleave, int):
            return InterleavePolicy(granularity=self.interleave)
        return INTERLEAVE_POLICIES[self.interleave]

    def engine_kwargs(self) -> Dict[str, Any]:
        """The knobs an engine constructor consumes, ready to splat."""
        return {"mode": self.mode, "coalesce": self.coalesce,
                "double_buffer": self.double_buffer, "unroll": self.unroll}

    def asdict(self) -> Dict[str, Any]:
        """JSON-ready dict, engine-default (``None``) knobs omitted."""
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}

    def label(self) -> str:
        return ",".join(f"{k}={v}" for k, v in self.asdict().items())


@dataclasses.dataclass
class Candidate:
    """One evaluated knob combination."""

    knobs: Knobs
    predicted_us: Optional[float] = None
    stats: Optional[Dict[str, float]] = None  # measure() dict once timed
    engine: Any = None
    fresh: Any = None
    error: Optional[str] = None
    # EquivalenceCertificate vs the base-knob program (tune(certify=True));
    # an equivalent certificate lets the candidate skip the numeric
    # ``check`` callback, a non-equivalent one disqualifies it pre-timing.
    certificate: Any = None

    @property
    def measured_ms(self) -> Optional[float]:
        return self.stats["med_s"] * 1e3 if self.stats else None


@dataclasses.dataclass
class TuneResult:
    """Search outcome: winner + the full (ordered) candidate record."""

    best: Candidate
    candidates: List[Candidate]

    @property
    def measured(self) -> List[Candidate]:
        return [c for c in self.candidates if c.stats is not None]

    def knobs_dict(self) -> Dict[str, Any]:
        return self.best.knobs.asdict()


def measure(engine, fresh, inner: int, repeats: int = 5,
            warm: bool = True) -> Dict[str, float]:
    """The bench harness's timing loop: ``inner`` chained engine calls,
    ``repeats`` times, re-materializing inputs outside the timed section
    (donating engines consume theirs).  ``warm`` runs one untimed call
    first so compiles never land in a timed repeat (pass ``False`` when
    the caller already warmed the engine).  Returns
    ``{avg_s, min_s, max_s, med_s}`` — the same row shape
    ``benchmarks/faces_bench.py`` reports, which delegates here.
    """
    import jax
    import numpy as np

    def _leaves(out):
        return jax.tree.leaves(out)

    if warm:
        engine(fresh())
    times = []
    for _ in range(repeats):
        m = fresh()
        t0 = time.perf_counter()
        for _ in range(inner):
            m = engine(m)
            if isinstance(m, tuple):  # (mem, reductions, ...) regimes
                m = m[0]
        jax.block_until_ready(_leaves(m))
        times.append(time.perf_counter() - t0)
    return {"avg_s": float(np.mean(times)), "min_s": float(np.min(times)),
            "max_s": float(np.max(times)), "med_s": float(np.median(times))}


def _expand_space(space: Dict[str, Sequence[Any]],
                  base: Knobs) -> List[Knobs]:
    import itertools
    names = list(space)
    for n in names:
        if n not in {f.name for f in dataclasses.fields(Knobs)}:
            raise ValueError(f"unknown knob {n!r} (have "
                             f"{[f.name for f in dataclasses.fields(Knobs)]})")
    out = []
    for combo in itertools.product(*(space[n] for n in names)):
        out.append(dataclasses.replace(base, **dict(zip(names, combo))))
    return out


def _lint(program) -> Optional[str]:
    """Error-severity STLint diagnostics, formatted — or None if clean."""
    from repro.core.verify import verify_program
    errors = [d for d in verify_program(program) if d.severity == "error"]
    if errors:
        return "; ".join(str(d) for d in errors)
    return None


def tune(
    build: Callable[[Knobs], Tuple[Any, Callable[[], Any]]],
    space: Dict[str, Sequence[Any]],
    *,
    base: Knobs = Knobs(),
    inner: int = 1,
    repeats: int = 3,
    measure_top: int = 3,
    engine_kind: Optional[str] = None,
    certify: bool = False,
    check: Optional[Callable[[Candidate], None]] = None,
    verbose: bool = False,
) -> TuneResult:
    """Search ``space`` over ``build``; return the measured winner.

    See the module docstring for the strategy.  ``engine_kind``
    overrides the cost model's dispatch model (inferred from the built
    engine's class otherwise); ``inner``/``repeats`` shape the timing
    loop exactly like the bench harness.  Raises ``ValueError`` when
    no candidate survives build+lint.

    ``certify=True`` builds the ``base``-knob program once and issues an
    :class:`~repro.core.effects.EquivalenceCertificate` for every
    candidate against it: a candidate whose per-buffer effect trace does
    not match the baseline's is disqualified *before* pricing or timing
    (``error="uncertified: ..."``), so a knob that silently changes
    numerics can never publish a number.  ``check`` is a numeric
    validator called with each measured candidate (raise to reject it);
    candidates holding an equivalent certificate **skip** it — the
    proof replaces the allclose.
    """
    import warnings

    from repro.launch.costing import schedule_cost

    baseline_prog = None
    if certify:
        from repro.core.effects import certify_equivalence
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")
            baseline_engine, _ = build(base)
        baseline_prog = baseline_engine.program

    candidates: List[Candidate] = []
    for knobs in _expand_space(space, base):
        cand = Candidate(knobs=knobs)
        candidates.append(cand)
        try:
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")  # lint explicitly below
                engine, fresh = build(knobs)
        except Exception as e:  # invalid point (ScheduleError, ...): skip
            cand.error = f"build: {type(e).__name__}: {e}"
            continue
        lint = _lint(engine.program)
        if lint is not None:  # never time an invalid program
            cand.error = f"stlint: {lint}"
            continue
        if baseline_prog is not None:
            cand.certificate = certify_equivalence(
                baseline_prog, engine.program)
            if not cand.certificate.equivalent:
                cand.error = f"uncertified: {cand.certificate.reason}"
                continue
        kind = engine_kind or (
            "persistent" if type(engine).__name__ == "PersistentEngine"
            else "fused")
        cand.engine, cand.fresh = engine, fresh
        cand.predicted_us = schedule_cost(
            engine.program, engine=kind, mode=knobs.mode,
            coalesce=knobs.coalesce, double_buffer=knobs.double_buffer,
        ).total_us
        if verbose:
            print(f"  tune: predict {cand.predicted_us:10.0f}us  "
                  f"[{knobs.label()}]", flush=True)

    viable = [c for c in candidates if c.error is None]
    if not viable:
        reasons = "; ".join(f"[{c.knobs.label()}] {c.error}"
                            for c in candidates)
        raise ValueError(f"no viable tuning candidate: {reasons}")
    viable.sort(key=lambda c: c.predicted_us)
    for cand in viable[:max(1, measure_top)]:
        cand.stats = measure(cand.engine, cand.fresh, inner, repeats)
        certified = (cand.certificate is not None
                     and cand.certificate.equivalent)
        if check is not None and not certified:
            try:
                check(cand)
            except Exception as e:  # numeric validation failed: reject
                cand.error = f"check: {type(e).__name__}: {e}"
                cand.stats = None
                continue
        if verbose:
            print(f"  tune: measure {cand.measured_ms:9.2f}ms  "
                  f"[{cand.knobs.label()}]"
                  + ("  [certified]" if certified else ""), flush=True)

    survivors = [c for c in viable if c.stats is not None
                 and c.error is None]
    if not survivors:
        reasons = "; ".join(f"[{c.knobs.label()}] {c.error}"
                            for c in candidates if c.error)
        raise ValueError(f"no measured candidate survived: {reasons}")
    best = min(survivors, key=lambda c: c.stats["med_s"])
    if verbose:
        print(f"  tune: best [{best.knobs.label()}] "
              f"med={best.measured_ms:.2f}ms "
              f"(searched {len(candidates)}, measured "
              f"{sum(1 for c in viable if c.stats)})", flush=True)
    return TuneResult(best=best, candidates=candidates)
