"""Modality frontend *stubs* (the one allowed carve-out, per brief).

``[audio]``/``[vlm]`` architectures get their conv/ViT feature extractor
stubbed: ``input_specs()`` supplies precomputed frame/patch embeddings of
the right shape, and this module provides only the *projector* that maps
them into the backbone's embedding space (which IS part of the language
model and is implemented + trained).
"""

from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .nn import param


def init_frontend(key, cfg: ModelConfig):
    dt = jnp.dtype(cfg.param_dtype)
    if cfg.frontend == "vision":
        ks = jax.random.split(key, 2)
        # InternVL-style 2-layer MLP projector
        return {
            "proj_in": param(ks[0], (cfg.frontend_dim, cfg.d_model),
                             ("frontend", "embed"), dt),
            "proj_out": param(ks[1], (cfg.d_model, cfg.d_model),
                              ("embed", "embed"), dt),
        }
    if cfg.frontend == "audio":
        # whisper stub supplies post-conv d_model embeddings; learn a
        # linear adapter (identity-scale init) + use sinusoidal positions
        return {
            "proj_in": param(key, (cfg.frontend_dim, cfg.d_model),
                             ("frontend", "embed"), dt, scale=0.01),
        }
    return {}


def apply_frontend(p, embeds: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Project stub embeddings into backbone space.  [B, T, F] → [B, T, D]."""
    dt = jnp.dtype(cfg.dtype)
    x = embeds.astype(dt)
    x = jnp.einsum("btf,fd->btd", x, p["proj_in"].astype(dt))
    if "proj_out" in p:
        x = jnp.einsum("btd,de->bte", jax.nn.gelu(x), p["proj_out"].astype(dt))
    return x


def sinusoidal_positions(n: int, d: int, dtype=jnp.float32) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
