"""grok-1-314b [moe] — 8 experts top-2, attention logit softcap.
[hf:xai-org/grok-1]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    arch_type="moe",
    source="hf:xai-org/grok-1",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    act="silu",   # gated expert FFN (3 matrices, grok-1 linear_v/linear_1/linear)
    rope_theta=10_000.0,
    attn_softcap=30.0,
    attn_output_multiplier=0.08838834764831845,
    n_experts=8,
    top_k=2,
    d_ff_expert=32768,
    router="softmax",
    capacity_factor=1.25,
    moe_impl="ep",          # virtual-expert shard_map dispatch (§Perf iter 3)
    long_context_ok=False,  # full attention → skip long_500k
)
