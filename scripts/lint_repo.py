#!/usr/bin/env python
"""Repo lint: jax version shims must come from ``repro/compat.py``.

ROADMAP rule: jax-version compatibility shims (shard_map / AxisType /
pallas CompilerParams / axis_size) live in ``src/repro/compat.py``; new
code imports the shim instead of feature-testing jax at call sites.
This AST lint enforces it:

* no ``getattr``/``hasattr`` feature-tests against the shimmed names
  outside compat.py — ``getattr(jax, "shard_map", None)`` scattered
  through call sites is exactly the drift the rule forbids;
* no direct ``jax.experimental.shard_map`` imports outside compat.py —
  the legacy spelling is compat.py's fallback, not an API.

A second rule guards the STProve effect substrate: shipped program
*builders* (:data:`EFFECT_DECLARING`) must pass explicit ``reads=`` and
``writes=`` to every ``enqueue_compute`` call.  The no-argument form is
a legal convenience for exploratory user code — the queue substitutes a
conservative reads-everything effect set and flags it ST019 — but in
shipped builders implicit effects over-serialize the happens-before
graph and weaken the race rules (ST015–ST018), so the AST lint bans it
at the source.

Scans ``src/``, ``tests/``, ``benchmarks/``, and ``scripts/``.  Prints
``file:line: message`` per violation and exits non-zero if any are
found (the CI lint job runs this next to ``python -m repro.analysis``).
"""

from __future__ import annotations

import ast
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SCAN_DIRS = ("src", "tests", "benchmarks", "scripts")
EXEMPT = os.path.join("src", "repro", "compat.py")

#: names whose presence-probing belongs in compat.py only
SHIMMED = {"shard_map", "axis_size", "AxisType", "CompilerParams",
           "TPUCompilerParams", "check_vma", "check_rep"}
LEGACY_MODULE = "jax.experimental.shard_map"

#: shipped builders where every enqueue_compute must declare its effect
#: set explicitly (reads= AND writes=) — see module docstring
EFFECT_DECLARING = {
    os.path.join("src", "repro", "core", "collectives.py"),
    os.path.join("src", "repro", "core", "halo.py"),
    os.path.join("src", "repro", "launch", "serve.py"),
}


def _feature_test_name(node: ast.Call):
    """The probed attribute name, if this call is getattr/hasattr with a
    literal name in the shimmed set."""
    fn = node.func
    if not (isinstance(fn, ast.Name) and fn.id in ("getattr", "hasattr")):
        return None
    if len(node.args) < 2:
        return None
    probe = node.args[1]
    if isinstance(probe, ast.Constant) and isinstance(probe.value, str) \
            and probe.value in SHIMMED:
        return probe.value
    return None


def _implicit_enqueue_compute(node: ast.Call) -> bool:
    """True when this is an ``<q>.enqueue_compute(...)`` call missing an
    explicit ``reads=`` or ``writes=`` keyword."""
    fn = node.func
    if not (isinstance(fn, ast.Attribute) and fn.attr == "enqueue_compute"):
        return False
    if any(isinstance(kw.arg, type(None)) for kw in node.keywords):
        return False  # **kwargs splat: can't see through it statically
    kws = {kw.arg for kw in node.keywords}
    return not {"reads", "writes"} <= kws


def lint_file(path: str, rel: str):
    with open(path, "r", encoding="utf-8") as f:
        src = f.read()
    try:
        tree = ast.parse(src, filename=rel)
    except SyntaxError as e:  # pragma: no cover - repo must parse
        return [(rel, e.lineno or 0, f"syntax error: {e.msg}")]

    declare_effects = rel in EFFECT_DECLARING
    out = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = _feature_test_name(node)
            if name is not None:
                out.append((rel, node.lineno,
                            f"feature-test of shimmed name {name!r} — "
                            f"import the shim from repro/compat.py instead"))
            if declare_effects and _implicit_enqueue_compute(node):
                out.append((rel, node.lineno,
                            "enqueue_compute without explicit reads=/"
                            "writes= — shipped builders must declare "
                            "effect sets (implicit fallback is ST019)"))
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.module.startswith(LEGACY_MODULE):
                out.append((rel, node.lineno,
                            f"direct import of {LEGACY_MODULE} — use "
                            f"repro.compat.shard_map"))
        elif isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.startswith(LEGACY_MODULE):
                    out.append((rel, node.lineno,
                                f"direct import of {LEGACY_MODULE} — use "
                                f"repro.compat.shard_map"))
    return out


def main(argv=None) -> int:
    violations = []
    for d in SCAN_DIRS:
        root = os.path.join(REPO, d)
        if not os.path.isdir(root):
            continue
        for dirpath, _, files in os.walk(root):
            for fn in sorted(files):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, REPO)
                if rel == EXEMPT or rel == os.path.join("scripts",
                                                        "lint_repo.py"):
                    continue
                violations.extend(lint_file(path, rel))

    for rel, line, msg in violations:
        print(f"{rel}:{line}: {msg}")
    if violations:
        print(f"lint_repo: {len(violations)} violation(s)", file=sys.stderr)
        return 1
    print("lint_repo: clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
